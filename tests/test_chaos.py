"""Chaos suite: fault-tolerant swapping end-to-end (ISSUE 8).

The acceptance invariants, exercised on REAL models (not toy stores —
those live in test_faults.py):

  * chaos property — under a seeded adversarial fault schedule (IOErrors,
    latency spikes, torn reads, corruption at p ~= 0.05) with retries
    enabled, a multi-block swapped forward returns BIT-IDENTICAL logits to
    the fault-free run, pass after pass;
  * zero-leak unwinding — after an injected unrecoverable failure the
    ledger running total returns exactly to its pre-pass value with zero
    leaked cache pins, and the very next (healed) pass serves correctly;
  * failure isolation — with model A's store wrapped in an always-failing
    injector, concurrent requests to model B on the same ServingScheduler
    complete within their deadlines while A's requests fail fast with
    SwapIOError (the per-model circuit breaker trips; reset_model()
    re-admits once the store heals);
  * cancellation — cancel-before-dispatch completes the request with
    RequestCancelled; cancel-while-running / after-completion returns
    False with no side effects;
  * load shedding — shed_deadlines=True rejects a request whose deadline
    expired while it queued (SwapTimeoutError) instead of running it late;
  * batch-engine eviction — a sequence whose prefill fails unrecoverably
    is evicted without poisoning the batch: co-batched sequences still
    produce their exact solo outputs and no KV pages leak.

The chaos seed is fixed by default for reproducibility; CI's chaos step
additionally runs the file under a randomized CHAOS_SEED (logged) to keep
the schedule adversarial rather than memorized.
"""
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel
from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import SwappedModel
from repro.core.serving_scheduler import ServingScheduler
from repro.core.swap_engine import MemoryLedger
from repro.errors import RequestCancelled, SwapIOError, SwapTimeoutError
from repro.models.transformer import Model
from repro.serving.batch_engine import BatchDecodeEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged_kv import PagedKVCache

from conftest import make_batch

MB = 1024 * 1024
# CI's chaos step overrides this (and logs its pick); locally the schedule
# is fixed so failures reproduce byte-for-byte
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    batch = make_batch(cfg, ShapeConfig("p", 32, 2, "prefill"))
    return cfg, model, params, batch


@pytest.fixture(scope="module")
def qwen():
    return _setup("qwen2.5-3b")


# ------------------------------------------------------- chaos property
def test_chaos_bit_identical_under_seeded_faults(qwen):
    """p ~= 0.05 adversarial schedule over repeated multi-block passes:
    every pass is bit-identical to the fault-free run, and the stats
    surface what the retry ladder absorbed."""
    cfg, model, params, batch = qwen
    with tempfile.TemporaryDirectory() as d_ref, \
            tempfile.TemporaryDirectory() as d_chaos:
        ref_sm = SwappedModel(model, params, d_ref, mode="snet")
        ref_sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=32)
        ref, _ = ref_sm.forward(batch)
        ref = np.asarray(ref)
        ref_sm.close()

        sm = SwappedModel(model, params, d_chaos, mode="snet",
                          store_backend="faulty",
                          store_options=dict(inner="mmap", p=0.05,
                                             seed=CHAOS_SEED,
                                             latency_s=0.002))
        sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=32)
        assert sm.plan.n_blocks >= 2
        # generous retry budget: the property under test is "retries make
        # faults invisible", not "the default budget survives this seed"
        sm.engine.read_retries = 6
        sm.engine.retry_backoff_s = 0.001
        total_faults = 0
        for _ in range(3):
            out, stats = sm.forward(batch)
            np.testing.assert_array_equal(np.asarray(out), ref)
            total_faults += sum(stats["faults"].values())
            assert stats["retries"] >= sum(stats["faults"].values())
        injected = dict(sm.store.injected)
        reads = sm.store.reads
        sm.close()
    assert sm.store.total_injected > 0, \
        f"seed {CHAOS_SEED} injected nothing over {reads} reads"
    # latency spikes delay but never fail; every FAILING injection must
    # have been absorbed by a retry (outputs already proved bit-identity)
    failing = sum(v for k, v in injected.items() if k != "latency")
    assert total_faults >= failing


def test_unrecoverable_failure_leaves_zero_leaks(qwen):
    """Exhaust the retry budget mid-pass: the error carries its attempt
    count, the ledger lands exactly on its pre-pass total, no cache lease
    survives, and the next (healed) pass is bit-identical."""
    cfg, model, params, batch = qwen
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet",
                          store_backend="faulty",
                          store_options=dict(inner="mmap", p=0.0,
                                             seed=CHAOS_SEED))
        sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=32)
        ref, _ = sm.forward(batch)                    # clean warm-up pass
        eng = sm.engine
        pre = eng.ledger.resident                     # cache-resident bytes
        assert pre == eng.cache.resident_bytes

        sm.store.p = 1.0                              # storage goes dark
        sm.store.mix = {"io": 1.0}
        with pytest.raises(SwapIOError) as ei:
            sm.forward(batch)
        assert ei.value.attempts == eng.read_retries + 1
        assert eng.ledger.resident == pre, \
            "failed pass leaked ledger bytes"
        assert eng.cache.active_leases() == {}, \
            "failed pass leaked cache pins"

        sm.store.p = 0.0                              # storage heals
        out, _ = sm.forward(batch)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert eng.ledger.resident == eng.cache.resident_bytes
        sm.close()


# ---------------------------------------------------- failure isolation
def test_failing_tenant_does_not_poison_cotenants(qwen):
    """Model A: always-failing store. Model B: healthy, same scheduler.
    A's requests all fail with SwapIOError (the breaker turns the tail of
    them into fast failures); B's requests complete within deadline with
    correct logits. reset_model() + healed storage re-admits A."""
    cfg_a, model_a, params_a, batch_a = qwen
    cfg_b, model_b, params_b, batch_b = _setup("gemma2-9b", seed=1)
    ref_a = np.asarray(jax.jit(model_a.prefill)(params_a, batch_a)[0][:, -1:])
    ref_b = np.asarray(jax.jit(model_b.prefill)(params_b, batch_b)[0][:, -1:])
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(24 * MB, cache_frac=0.25, executors=2)
        rt.add_model("bad", model_a, params_a, d, store_backend="faulty",
                     store_options=dict(inner="mmap", p=1.0,
                                        mix={"io": 1.0}, seed=CHAOS_SEED))
        rt.add_model("good", model_b, params_b, d)
        rt.plan(batch=2, seq=32)
        rt.models["bad"].engine.retry_backoff_s = 0.001
        with ServingScheduler(rt, fail_fast_after=2) as sched:
            bad = [sched.submit("bad", batch_a) for _ in range(4)]
            good = [sched.submit("good", batch_b, deadline=120.0)
                    for _ in range(3)]
            for r in good:
                r.wait(timeout=300)
            for r in bad:
                with pytest.raises(SwapIOError):
                    r.wait(timeout=300)
                assert r.error.model == "bad"
            # same-model passes serialize, so exactly fail_fast_after
            # requests burned a real retry ladder; the rest failed fast
            assert sched.failed_fast == 2
            assert isinstance(sched.model_down("bad"), SwapIOError)
            assert sched.model_down("good") is None
            for r in good:
                np.testing.assert_allclose(np.asarray(r.logits), ref_b,
                                           rtol=1e-4, atol=1e-4)
                assert r.latency_s <= 120.0, "co-tenant blew its deadline"

            # operator fixes the storage and re-admits the model
            rt.models["bad"].store.p = 0.0
            sched.reset_model("bad")
            healed = sched.submit("bad", batch_a).wait(timeout=300)
            np.testing.assert_allclose(np.asarray(healed.logits), ref_a,
                                       rtol=1e-4, atol=1e-4)
        assert rt.ledger.resident == rt.cache.resident_bytes
        rt.close()


# --------------------------------------------------------- cancellation
def _spin_until(pred, timeout=10.0):
    deadline = time.perf_counter() + timeout
    while not pred():
        assert time.perf_counter() < deadline, "condition never held"
        time.sleep(0.002)


def test_cancel_before_dispatch_and_while_running(qwen):
    cfg, model, params, batch = qwen
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(12 * MB, cache_frac=0.25, executors=1)
        rt.add_model("q", model, params, d)
        rt.plan(batch=2, seq=32)
        with ServingScheduler(rt, executors=1) as sched:
            r1 = sched.submit("q", batch)
            r2 = sched.submit("q", batch)
            # r1 gets popped; r2 stays queued behind the busy model
            _spin_until(lambda: len(sched.queue) == 1)
            assert sched.cancel(r2.rid) is True
            with pytest.raises(RequestCancelled):
                r2.wait(timeout=5)
            r1.wait(timeout=300)
            # running-or-completed requests are NOT cancellable
            assert sched.cancel(r1.rid) is False
            assert sched.cancel(9999) is False          # unknown rid
            r3 = sched.submit("q", batch)
            _spin_until(lambda: len(sched.queue) == 0)  # r3 dispatched
            assert sched.cancel(r3.rid) is False
            r3.wait(timeout=300)                        # unharmed
            assert r3.error is None and r3.logits is not None
        assert [r.rid for r in sched.completed] == [r1.rid, r3.rid]
        rt.close()


# --------------------------------------------------------- load shedding
def test_shed_deadline_expired_while_queued(qwen):
    cfg, model, params, batch = qwen
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(12 * MB, cache_frac=0.25, executors=1)
        rt.add_model("q", model, params, d)
        rt.plan(batch=2, seq=32)
        with ServingScheduler(rt, executors=1,
                              shed_deadlines=True) as sched:
            r1 = sched.submit("q", batch)
            _spin_until(lambda: len(sched.queue) == 0)  # r1 running
            # queued behind r1's whole pass; its 1 ms deadline is ancient
            # history by the time an executor could take it
            r2 = sched.submit("q", batch, deadline=0.001)
            with pytest.raises(SwapTimeoutError):
                r2.wait(timeout=300)
            assert r2.error.model == "q"
            r1.wait(timeout=300)
            assert r1.error is None
        assert sched.shed == 1
        assert sched.model_down("q") is None    # shedding is not a failure
        rt.close()


# --------------------------------------------- batch-engine survivability
def test_batch_engine_evicts_failed_sequence(qwen):
    """One sequence's prefill fails unrecoverably mid-admission: it is
    evicted with the error attached, its pages return to the pool, and the
    co-batched sequences still emit their exact solo outputs."""
    cfg, model, params, batch = qwen
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    eng = ServingEngine(model, params, max_len=64)

    def solo(prompt, max_new):
        r = Request(0, list(prompt), max_new_tokens=max_new)
        eng.generate([r])
        return list(r.output)

    want = {0: solo(prompts[0], 4), 2: solo(prompts[2], 3)}
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=16)
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=8)
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        real_prefill = be._prefill

        def prefill(req):
            if req.rid == 1:
                raise SwapIOError("injected prefill failure", unit="q/blk0")
            return real_prefill(req)
        be._prefill = prefill

        reqs = [Request(0, list(prompts[0]), max_new_tokens=4),
                Request(1, list(prompts[1]), max_new_tokens=4),
                Request(2, list(prompts[2]), max_new_tokens=3)]
        retired = []
        for r in reqs:
            be.submit(r, on_retire=lambda rr: retired.append(rr.rid))
        be.run_all()
        sm.close()
    assert isinstance(reqs[1].error, SwapIOError)
    assert reqs[1].error.model == sm.name       # engine tags the tenant
    assert reqs[1].output == []                 # no tokens from a failure
    assert list(reqs[0].output) == want[0]
    assert list(reqs[2].output) == want[2]
    assert be.failures == 1
    assert [r for t in be.trace for r in t.failed] == [1]
    assert sorted(r for t in be.trace for r in t.retired) == [0, 2]
    assert sorted(retired) == [0, 1, 2]         # callback fires either way
    assert be.is_done(1)
    assert kv.pages_in_use == 0, "evicted sequence leaked KV pages"
    assert be.stats()["failures"] == 1.0


def test_batch_engine_cancel_is_pending_only(qwen):
    cfg, model, params, batch = qwen
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=16)
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=8)
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        fired = []
        reqs = [Request(i, list(prompts[i]), max_new_tokens=3)
                for i in range(3)]
        for r in reqs:
            be.submit(r, on_retire=lambda rr: fired.append(rr.rid))
        assert be.cancel(2) is True             # still pending: removable
        assert be.cancel(2) is False            # idempotent: already gone
        be.step()                               # admits rids 0 and 1
        assert be.cancel(0) is False            # admitted: must retire
        be.run_all()
        sm.close()
    assert sorted(fired) == [0, 1]              # rid 2's callback never fires
    assert reqs[2].output == []
    assert not any(2 in t.admitted for t in be.trace)
    assert kv.pages_in_use == 0
